"""Benchmark harness — one benchmark per paper claim/figure plus kernel
micro-benches and the roofline table.  Prints ``name,us_per_call,derived``
CSV rows (derived = the claim-relevant figure of merit).

  r1_dataset_reduction   R1: tokenize+pack ahead of time (paper: 2TB->25GB)
  r2_staging             R2: node-local staging beats contended network FS
  r3_loader_workers      R3: loader worker count vs utilization
  fig1_dp_scaling        Fig. 1: samples/s vs worker count (120M & 350M)
  r5_batch_vs_model      R5: max per-GPU batch 184 (120M) vs 20 (350M)
  mlm_train_step         measured train-step time of the paper's model (CPU)
  train_overlap          dispatch-stall fraction: seed-style blocking loop
                         vs the sharding-aware async StepRunner/TrainLoop
  grad_overlap           ddp gradient sync on an 8-device CPU mesh:
                         bucketed/backward-overlapped psum vs the fused
                         tail all-reduce — step time, dispatch stall, and
                         grad equivalence (microbatches 1 and 4)
  fsdp_overlap           fsdp (ZeRO-3) on an 8-device CPU mesh: the
                         scatter_overlap step (per-bucket all_gather
                         prefetch + psum_scatter) vs the XLA-fused fsdp
                         baseline — grad equivalence, 20-step loss
                         trajectory, per-bucket comm bytes, the ~2x
                         gradient wire-byte drop vs the ddp all-reduce,
                         and the donate_gather peak-memory delta
  pipeline_overlap       pipeline parallelism (2 stages x 4 dp on 8 CPU
                         devices): staged 1F1B/GPipe step vs the
                         unpipelined ddp runner — grad equivalence at
                         microbatches 2 and 8, 20-step 1F1B loss
                         trajectory, schedule bubble fraction vs the
                         analytic (S-1)/(S-1+M) bound, activation
                         ppermute volume
  moe_overlap            expert-parallel MoE (4 data x 2 expert on 8 CPU
                         devices): capacity-bucketed all_to_all dispatch
                         with the shared-expert FFN overlapping the
                         exchange — EP grads vs the dense one-hot oracle
                         at microbatches 1 and 4, bucketed-ddp MoE grads
                         vs the same oracle (psum'd router statistics),
                         20-step EP loss trajectory, overlapped vs
                         sequential dispatch step time
  data_pipeline          deterministic pipeline vs seed loader throughput,
                         per-host shard disjointness, resume overhead
  trace_overhead         observability cost on the hot loop: the same
                         TrainLoop run untraced (NullTracer fast path)
                         vs with a live Tracer + metrics registry —
                         asserted <=3% step-time overhead
  serve_bench            paged KV + continuous batching vs the static
                         lockstep engine: Poisson arrivals over mixed
                         prompt/output lengths — useful tokens/s,
                         p50/p95 request latency (in decode steps),
                         KV-pool utilization, decode compile count
                         (asserted: >=2x throughput, zero recompiles)
  kernel_*               Pallas kernels (interpret mode) vs jnp oracle
  roofline_table         aggregated dry-run roofline terms (if present)

Pass bench-name prefixes as argv to run a subset, and ``--json PATH`` to
also write the rows as a JSON list (CI uploads it as an artifact), e.g.:

  PYTHONPATH=src python benchmarks/run.py train_overlap kernel --json out.json

``--baseline`` additionally lands the rows as committed trajectories —
one ``BENCH_<group>.json`` per benchmark group at the repo root.  CI
compares every fresh ``--json`` artifact against those with
``tools/check_bench_regression.py`` and fails on a >15% step-time
regression (overlap-vs-baseline ratio, so the gate is machine-speed
independent).  After an intentional perf change, re-run with
``--baseline`` and commit the updated files.

Every JSON file carries a shared ``meta`` block (bench environment:
device count, mesh shape, jax version, platform; pass ``--meta-sha``
to stamp the git revision) next to the ``rows`` list, so artifacts are
self-describing.  ``check_bench_regression.py`` ignores the block and
also still reads the older bare-list format.
"""
from __future__ import annotations

import glob
import json
import os
import sys
import tempfile
import time

ROW = "{name},{us:.1f},{derived}"
RESULTS: list = []


def emit(name: str, us: float, derived: str):
    print(ROW.format(name=name, us=us, derived=derived))
    RESULTS.append({"name": name, "us_per_call": round(us, 1),
                    "derived": derived})


def _t(fn, n=3):
    fn()  # warm
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6


def _meta(sha=None):
    """Shared ``meta`` block written next to ``rows`` in every JSON
    artifact: the bench environment, so a downloaded artifact is
    self-describing.  Benchmarks that need more devices re-exec in a
    subprocess with their own XLA_FLAGS, so the mesh here is the
    top-level harness's view."""
    import jax

    from repro.configs import get_config
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh()
    return {
        "config": get_config("bert-mlm-120m").name,
        "mesh_shape": {k: int(v) for k, v in mesh.shape.items()},
        "device_count": jax.device_count(),
        "platform": jax.devices()[0].platform,
        "jax_version": jax.__version__,
        "git_sha": sha,
    }


# ---------------------------------------------------------------------------


def bench_r1_dataset_reduction(tmp):
    from repro.data import (ByteBPETokenizer, pack_corpus, read_raw_corpus,
                            size_reduction, write_raw_corpus)

    raw = os.path.join(tmp, "raw.jsonl")
    t0 = time.perf_counter()
    nbytes = write_raw_corpus(raw, 1500, seed=0)
    fns = list(read_raw_corpus(raw))
    tok = ByteBPETokenizer.train(fns[:60], max_merges=300)
    shards = pack_corpus(iter(fns), tok, os.path.join(tmp, "packed"),
                         seq_len=512)
    us = (time.perf_counter() - t0) * 1e6
    red = size_reduction(nbytes, shards)
    emit(name="r1_dataset_reduction", us=us,
                     derived=f"reduction={red*100:.1f}%_paper=99%")
    return shards


def bench_r2_staging(tmp, shards):
    from repro.data import NetworkFS, StagedDataset, measure_throughput

    net = StagedDataset(list(shards),
                        network=NetworkFS(agg_bw=2e9, readers=128))
    m_net = measure_throughput(net, 64, 2, n_batches=40)
    local = StagedDataset(list(shards),
                          network=NetworkFS(agg_bw=2e9, readers=128),
                          local_dir=os.path.join(tmp, "local"))
    stage_s = local.stage()
    m_loc = measure_throughput(local, 64, 2, n_batches=40)
    speed = m_loc["samples_per_s"] / max(m_net["samples_per_s"], 1e-9)
    emit(name="r2_staging", us=stage_s * 1e6,
                     derived=f"staged_speedup={speed:.2f}x")


def bench_r3_loader_workers(tmp, shards):
    from repro.data import StagedDataset, tune_workers

    ds = StagedDataset(list(shards))
    t0 = time.perf_counter()
    out = tune_workers(ds, 64, step_time_s=0.003, max_workers=4,
                       target_util=0.9, n_batches=25)
    us = (time.perf_counter() - t0) * 1e6
    hist = ";".join(f"w{h['n_workers']}:util={h['utilization']:.2f}"
                    for h in out["history"])
    emit(name="r3_loader_workers", us=us,
                     derived=f"chosen={out['chosen']}_{hist}")


def bench_fig1_dp_scaling():
    from repro.configs import get_config
    from repro.core import H100_NVL, TPU_V5E, dp_scaling_curve

    t0 = time.perf_counter()
    rows = []
    for arch, b in (("bert-mlm-120m", 184), ("bert-mlm-350m", 20)):
        cfg = get_config(arch)
        curve = dp_scaling_curve(cfg, per_dev_batch=b, chip=H100_NVL,
                                 seq=512)
        rows.append(f"{arch}:eff@256={curve[256]['efficiency']:.2f}")
        tcurve = dp_scaling_curve(cfg, per_dev_batch=b, chip=TPU_V5E,
                                  seq=512)
        rows.append(f"{arch}-v5e:eff@256={tcurve[256]['efficiency']:.2f}")
    us = (time.perf_counter() - t0) * 1e6
    emit(name="fig1_dp_scaling", us=us,
                     derived="_".join(rows) + "_paper=near-linear")


def bench_r5_batch_vs_model():
    from repro.configs import get_config
    from repro.core import H100_NVL, MemoryModel

    t0 = time.perf_counter()
    b = {}
    for arch in ("bert-mlm-120m", "bert-mlm-350m"):
        mm = MemoryModel(get_config(arch), act_factor=150.0)
        b[arch] = mm.max_batch(512, H100_NVL.hbm_bytes)
    us = (time.perf_counter() - t0) * 1e6
    ratio = b["bert-mlm-120m"] / max(1, b["bert-mlm-350m"])
    emit(
        name="r5_batch_vs_model", us=us,
        derived=(f"b120={b['bert-mlm-120m']}_b350={b['bert-mlm-350m']}"
                 f"_ratio={ratio:.1f}_paper=184/20=9.2"))


def bench_mlm_train_step():
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, reduced
    from repro.configs.base import RunConfig, ShapeConfig
    from repro.models import build_model
    from repro.train.optimizer import AdamWConfig
    from repro.train.train_step import init_state, make_train_step

    cfg = reduced(get_config("bert-mlm-120m"), d_model=256)
    model = build_model(cfg)
    B, S = 8, 128
    run = RunConfig(model=cfg, shape=ShapeConfig("b", S, B, "train"),
                    sharding="ddp", param_dtype="float32",
                    activation_dtype="float32")
    step = jax.jit(make_train_step(model, run, AdamWConfig()))
    state = init_state(model, jax.random.PRNGKey(0), run)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 4,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks,
             "loss_mask": jnp.ones((B, S), jnp.float32)}
    state_box = [state]

    def one():
        s, m = step(state_box[0], batch)
        jax.block_until_ready(m["loss"])
        state_box[0] = s

    us = _t(one, n=3)
    tok_s = B * S / (us / 1e6)
    emit(name="mlm_train_step", us=us,
                     derived=f"tokens_per_s={tok_s:.0f}_cpu_host")


def bench_train_overlap(tmp):
    """Dispatch-stall fraction, seed-style loop vs StepRunner/TrainLoop.

    Both loops run the same model/batches/checkpoint cadence and account
    host-blocked time identically: time spent waiting in batch fetch +
    blocking metric conversion + checkpoint writes + the final sync,
    divided by total wall time.  The seed loop is the pre-runner trainer
    verbatim (bare jax.jit, float(metrics) at every log step, synchronous
    np.savez checkpointing, no device prefetch); the runner overlaps all
    three off the critical path.
    """
    import dataclasses

    import jax
    import numpy as np

    from repro.configs import get_config, reduced
    from repro.configs.base import RunConfig, ShapeConfig
    from repro.launch.mesh import make_host_mesh
    from repro.models import build_model
    from repro.train import checkpoint as ckpt
    from repro.train.optimizer import AdamWConfig
    from repro.train.runner import StepRunner, TrainLoop
    from repro.train.train_step import init_state, make_train_step

    B, S, STEPS, LOG_EVERY, CKPT_EVERY = 8, 64, 24, 1, 8
    cfg = dataclasses.replace(reduced(get_config("bert-mlm-120m"),
                                      d_model=128),
                              vocab_size=512, max_position=S)
    model = build_model(cfg)
    run = RunConfig(model=cfg, shape=ShapeConfig("b", S, B, "train"),
                    sharding="ddp", param_dtype="float32",
                    activation_dtype="float32")
    opt = AdamWConfig(total_steps=STEPS)

    def batches(seed=0):
        rng = np.random.default_rng(seed)
        while True:
            toks = rng.integers(4, cfg.vocab_size, (B, S)).astype(np.int32)
            yield {"tokens": toks, "labels": toks,
                   "loss_mask": np.ones((B, S), np.float32)}

    # -- seed-style loop (pre-runner trainer.train, instrumented) ---------
    # one persistent jit so the warmup call below compiles it; the
    # measured pass is pure steady-state dispatch, like the runner's
    seed_step_fn = jax.jit(make_train_step(model, run, opt))

    def seed_loop(ckpt_path):
        import jax.numpy as jnp

        step_fn = seed_step_fn
        state = init_state(model, jax.random.PRNGKey(0), run)
        it = iter(batches())
        blocked = 0.0
        t0 = time.perf_counter()
        for i in range(STEPS):
            batch = {k: jnp.asarray(v) for k, v in next(it).items()}
            state, metrics = step_fn(state, batch)
            if (i + 1) % LOG_EVERY == 0 or i == 0 or i == STEPS - 1:
                tb = time.perf_counter()
                _ = {k: float(v) for k, v in metrics.items()}  # blocks
                blocked += time.perf_counter() - tb
            if (i + 1) % CKPT_EVERY == 0:
                tb = time.perf_counter()
                ckpt.save(ckpt_path, state, step=i + 1)  # sync serialize
                blocked += time.perf_counter() - tb
        tb = time.perf_counter()
        jax.block_until_ready(state)
        blocked += time.perf_counter() - tb
        total = time.perf_counter() - t0
        return blocked / total, total

    # warm BOTH paths' compiles out-of-band so the measured passes are
    # steady-state dispatch behaviour, not compile time
    seed_loop(os.path.join(tmp, "warm_seed"))
    runner = StepRunner(model, run, opt, make_host_mesh())
    TrainLoop(runner, log_every=LOG_EVERY).run(batches(1), 2)

    t0 = time.perf_counter()
    seed_stall, seed_total = seed_loop(os.path.join(tmp, "ck_seed"))

    loop = TrainLoop(runner, log_every=LOG_EVERY,
                     ckpt_path=os.path.join(tmp, "ck_runner"),
                     ckpt_every=CKPT_EVERY)
    _, log = loop.run(batches(), STEPS)
    t = log.telemetry
    us = (time.perf_counter() - t0) * 1e6
    emit(
        name="train_overlap", us=us,
        derived=(f"stall_seed={seed_stall:.3f}_stall_runner="
                 f"{t['stall_fraction']:.3f}_compiles={t['n_traces']:.0f}"
                 f"_tokens_per_s={t['tokens_per_s']:.0f}"))
    assert t["stall_fraction"] < seed_stall, (
        "async runner must stall less than the seed-style loop",
        t["stall_fraction"], seed_stall)


def bench_trace_overhead(tmp):
    """Observability cost on the hot loop (the ISSUE's <=3% budget).

    The same StepRunner/TrainLoop runs the same batches twice per pass:
    untraced (the NullTracer fast path — a shared no-op span, zero
    allocation) and traced (live Tracer ring buffer + metrics registry
    + JSONL emission at every log window).  Single passes jitter +-15%
    on shared CI runners — far above the effect being measured — so
    passes are interleaved A/B and the best-of-6 wall time per variant
    is compared: tracer cost is systematic (paid on every pass), so the
    floor still contains it while the scheduler noise washes out.  The
    committed
    ``step_untraced=..ms_traced=..ms`` ratio additionally rides the CI
    15% drift gate via BENCH_trace_overhead.json.
    """
    import dataclasses

    import numpy as np

    from repro.configs import get_config, reduced
    from repro.configs.base import RunConfig, ShapeConfig
    from repro.launch.mesh import make_host_mesh
    from repro.models import build_model
    from repro.observability import NULL_TRACER, MetricsRegistry, Tracer
    from repro.train.optimizer import AdamWConfig
    from repro.train.runner import StepRunner, TrainLoop

    B, S, STEPS, LOG_EVERY = 8, 64, 40, 4
    cfg = dataclasses.replace(reduced(get_config("bert-mlm-120m"),
                                      d_model=128),
                              vocab_size=512, max_position=S)
    model = build_model(cfg)
    run = RunConfig(model=cfg, shape=ShapeConfig("b", S, B, "train"),
                    sharding="ddp", param_dtype="float32",
                    activation_dtype="float32")
    opt = AdamWConfig(total_steps=STEPS)
    runner = StepRunner(model, run, opt, make_host_mesh())

    def batches(seed=0):
        rng = np.random.default_rng(seed)
        while True:
            toks = rng.integers(4, cfg.vocab_size, (B, S)).astype(np.int32)
            yield {"tokens": toks, "labels": toks,
                   "loss_mask": np.ones((B, S), np.float32)}

    tracer = Tracer()
    registry = MetricsRegistry()
    jsonl = os.path.join(tmp, "metrics.jsonl")

    def run_once(traced):
        loop = TrainLoop(
            runner, log_every=LOG_EVERY,
            tracer=tracer if traced else NULL_TRACER,
            metrics=registry if traced else None,
            metrics_jsonl=jsonl if traced else None)
        t0 = time.perf_counter()
        loop.run(batches(2), STEPS)
        return time.perf_counter() - t0

    run_once(False)  # warm compile (shared runner: one jit entry)
    run_once(True)
    t_off, t_on = [], []
    for _ in range(6):
        t_off.append(run_once(False))
        t_on.append(run_once(True))
    off, on = min(t_off), min(t_on)
    ratio = on / off
    emit(name="trace_overhead_step", us=on / STEPS * 1e6,
         derived=(f"step_untraced={off/STEPS*1e3:.2f}ms_traced="
                  f"{on/STEPS*1e3:.2f}ms_ratio={ratio:.3f}"
                  f"_events={len(tracer)}_dropped={tracer.dropped}"))
    assert ratio <= 1.03, (
        f"tracing overhead {100*(ratio-1):.1f}% exceeds the 3% budget",
        t_off, t_on)


def _grad_overlap_worker():
    """Runs in a subprocess with 8 virtual CPU devices (the parent sets
    XLA_FLAGS); prints one JSON line.  Compares the ParallelPlan's two ddp
    grad-sync strategies on identical model/batches:

      fused_tail — ``overlap=False``: the pjit path, one partitioner-
                   scheduled all-reduce after the full backward
      bucketed   — the shard_map step, one psum per reverse-layer bucket

    and checks the bucketed gradients against the single-device fused
    reference (rtol 1e-6 at per-leaf scale, 1e-8 absolute floor for
    f32 reduction-order noise) for microbatches 1 and 4.
    """
    import dataclasses

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config, reduced
    from repro.configs.base import RunConfig, ShapeConfig
    from repro.distributed.sharding import ParallelPlan
    from repro.launch.mesh import make_host_mesh
    from repro.models import build_model
    from repro.train.optimizer import AdamWConfig
    from repro.train.runner import StepRunner, TrainLoop
    from repro.train.train_step import init_state, make_grad_fn

    # B=32 over 8 dp shards: local batch 4 — divisible by both microbatch
    # counts below (the bucketed path splits the LOCAL shard)
    B, S, STEPS = 32, 64, 24
    cfg = dataclasses.replace(reduced(get_config("bert-mlm-120m"),
                                      d_model=128),
                              vocab_size=512, max_position=S)
    model = build_model(cfg)
    mesh = make_host_mesh(8)
    opt = AdamWConfig(total_steps=STEPS)
    out = {"equiv": {}}

    def batches(seed=0):
        rng = np.random.default_rng(seed)
        while True:
            toks = rng.integers(4, cfg.vocab_size, (B, S)).astype(np.int32)
            yield {"tokens": toks, "labels": toks,
                   "loss_mask": np.ones((B, S), np.float32)}

    # -- gradient equivalence --------------------------------------------
    for n_micro in (1, 4):
        run = RunConfig(model=cfg, shape=ShapeConfig("b", S, B, "train"),
                        sharding="ddp", param_dtype="float32",
                        activation_dtype="float32", microbatch=n_micro)
        params = init_state(model, jax.random.PRNGKey(0), run)["params"]
        batch = {k: jnp.asarray(v) for k, v in next(batches(7)).items()}
        _, gref, mref = jax.jit(make_grad_fn(model, run))(params, batch)
        plan = ParallelPlan.for_run(run, mesh, grad_bucket_mb=0.25)
        assert plan.grad_sync == "bucketed_overlap", plan.describe()
        _, gb, mb = jax.jit(make_grad_fn(model, run, mesh, plan))(
            params, batch)
        worst = 0.0
        for a, b in zip(jax.tree_util.tree_leaves(gref),
                        jax.tree_util.tree_leaves(gb)):
            a, b = np.asarray(a), np.asarray(b)
            tol = 1e-6 * max(float(np.abs(a).max()), 1.0) + 1e-8
            worst = max(worst, float(np.abs(a - b).max()) / tol)
        out["equiv"][str(n_micro)] = {
            "worst_err_over_tol": worst,
            "loss_match": abs(float(mref["loss"]) - float(mb["loss"]))
                          <= 1e-6 * abs(float(mref["loss"])),
        }

    # -- step time + dispatch stall --------------------------------------
    run = RunConfig(model=cfg, shape=ShapeConfig("b", S, B, "train"),
                    sharding="ddp", param_dtype="float32",
                    activation_dtype="float32")

    def measure(overlap):
        plan = ParallelPlan.for_run(run, mesh, grad_bucket_mb=0.25,
                                    overlap=overlap)
        runner = StepRunner(model, run, opt, mesh, plan=plan)
        TrainLoop(runner, log_every=8).run(batches(1), 3)  # warm compile
        _, log = TrainLoop(runner, log_every=8).run(batches(2), STEPS)
        t = log.telemetry
        return {"stall": t["stall_fraction"],
                "step_ms": t["step_time_ema"] * 1e3,
                "tokens_per_s": t["tokens_per_s"],
                "n_buckets": t["grad_buckets"],
                "comm_mb": t["grad_comm_bytes"] / 1e6,
                "wire_mb": t["grad_wire_bytes_per_device"] / 1e6}

    out["fused"] = measure(False)
    out["bucketed"] = measure(True)

    # -- bucket-size sweep: step time vs grad_bucket_mb ------------------
    # the tiny bench model collapses large sizes to one bucket; the row
    # still pins the sweep machinery and makes bucket-count regressions
    # (a planner change that suddenly fragments buckets) visible
    sweep = {}
    for mb in (8, 25, 64):
        plan = ParallelPlan.for_run(run, mesh, grad_bucket_mb=float(mb))
        runner = StepRunner(model, run, opt, mesh, plan=plan)
        TrainLoop(runner, log_every=8).run(batches(1), 3)  # warm compile
        _, log = TrainLoop(runner, log_every=8).run(batches(2), STEPS)
        t = log.telemetry
        sweep[str(mb)] = {"step_ms": t["step_time_ema"] * 1e3,
                          "n_buckets": t["grad_buckets"],
                          "stall": t["stall_fraction"]}
    out["bucket_sweep"] = sweep
    print(json.dumps(out))


def bench_grad_overlap():
    import subprocess
    import sys as _sys

    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + (os.pathsep + env["PYTHONPATH"]
                               if env.get("PYTHONPATH") else "")
    t0 = time.perf_counter()
    proc = subprocess.run(
        [_sys.executable, os.path.abspath(__file__),
         "--grad-overlap-worker"],
        env=env, capture_output=True, text=True, timeout=900)
    us = (time.perf_counter() - t0) * 1e6
    assert proc.returncode == 0, proc.stderr[-3000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])

    f, b = out["fused"], out["bucketed"]
    emit(name="grad_overlap_step", us=us,
         derived=(f"step_fused={f['step_ms']:.1f}ms_bucketed="
                  f"{b['step_ms']:.1f}ms_buckets={b['n_buckets']}"
                  f"_comm={b['comm_mb']:.2f}MB_wire="
                  f"{b['wire_mb']:.2f}MB/dev"))
    emit(name="grad_overlap_stall", us=0,
         derived=(f"stall_fused={f['stall']:.3f}_stall_bucketed="
                  f"{b['stall']:.3f}"))
    sw = out["bucket_sweep"]
    emit(name="grad_overlap_bucket_sweep", us=0,
         derived=("_".join(f"mb{k}={v['step_ms']:.1f}ms"
                           for k, v in sw.items())
                  + "_buckets="
                  + "/".join(str(v["n_buckets"]) for v in sw.values())))
    e1, e4 = out["equiv"]["1"], out["equiv"]["4"]
    emit(name="grad_overlap_equiv", us=0,
         derived=(f"err_over_tol_micro1={e1['worst_err_over_tol']:.2f}"
                  f"_micro4={e4['worst_err_over_tol']:.2f}"
                  f"_loss_match={e1['loss_match'] and e4['loss_match']}"))
    for e in (e1, e4):
        assert e["worst_err_over_tol"] <= 1.0 and e["loss_match"], (
            "bucketed ddp grads must match the fused reference", out)
    # 0.05 absolute slack: CPU wall-clock noise on an all-virtual mesh
    assert b["stall"] <= f["stall"] + 0.05, (
        "bucketed-overlap dispatch stall must not exceed the fused-tail "
        "baseline", out)


def _fsdp_overlap_worker():
    """Runs in a subprocess with 8 virtual CPU devices; prints one JSON
    line.  Compares the ParallelPlan's two fsdp grad-sync strategies on
    identical model/batches:

      fused   — ``overlap=False``: the pjit path; the partitioner derives
                collectives from the embed-rule param shardings
      scatter — ``scatter_overlap``: params + optimizer state sharded
                over "data"; the shard_map step all_gathers each param
                bucket in forward order and psum_scatters each grad
                bucket in reverse order

    Checks (same tolerances as grad_overlap): scatter gradients vs the
    single-device fused reference for microbatches 1 and 4, and a
    20-step loss trajectory vs the XLA-fused fsdp runner.  Also reports
    per-bucket comm bytes and the gradient wire-byte ratio vs a ddp ring
    all-reduce of the same payload (reduce-scatter alone is the
    reduce-scatter half: ~0.5x).
    """
    import dataclasses

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config, reduced
    from repro.configs.base import RunConfig, ShapeConfig
    from repro.distributed import gradsync
    from repro.distributed.sharding import ParallelPlan
    from repro.launch.mesh import make_host_mesh
    from repro.models import build_model
    from repro.train.optimizer import AdamWConfig
    from repro.train.runner import StepRunner, TrainLoop
    from repro.train.train_step import init_state, make_grad_fn

    B, S, STEPS = 32, 64, 20
    cfg = dataclasses.replace(reduced(get_config("bert-mlm-120m"),
                                      d_model=128),
                              vocab_size=512, max_position=S)
    model = build_model(cfg)
    mesh = make_host_mesh(8)
    opt = AdamWConfig(total_steps=STEPS)
    out = {"equiv": {}}

    def batches(seed=0):
        rng = np.random.default_rng(seed)
        while True:
            toks = rng.integers(4, cfg.vocab_size, (B, S)).astype(np.int32)
            yield {"tokens": toks, "labels": toks,
                   "loss_mask": np.ones((B, S), np.float32)}

    # -- gradient equivalence --------------------------------------------
    for n_micro in (1, 4):
        run = RunConfig(model=cfg, shape=ShapeConfig("b", S, B, "train"),
                        sharding="fsdp", param_dtype="float32",
                        activation_dtype="float32", microbatch=n_micro)
        params = init_state(model, jax.random.PRNGKey(0), run)["params"]
        batch = {k: jnp.asarray(v) for k, v in next(batches(7)).items()}
        _, gref, mref = jax.jit(make_grad_fn(model, run))(params, batch)
        plan = ParallelPlan.for_run(run, mesh, grad_bucket_mb=0.25)
        assert plan.grad_sync == "scatter_overlap", plan.describe()
        _, gs_, ms_ = jax.jit(make_grad_fn(model, run, mesh, plan))(
            params, batch)
        worst = 0.0
        for a, b in zip(jax.tree_util.tree_leaves(gref),
                        jax.tree_util.tree_leaves(gs_)):
            a, b = np.asarray(a), np.asarray(b)
            tol = 1e-6 * max(float(np.abs(a).max()), 1.0) + 1e-8
            worst = max(worst, float(np.abs(a - b).max()) / tol)
        out["equiv"][str(n_micro)] = {
            "worst_err_over_tol": worst,
            "loss_match": abs(float(mref["loss"]) - float(ms_["loss"]))
                          <= 1e-6 * abs(float(mref["loss"])),
        }

    # -- 20-step loss trajectory + step time / stall ---------------------
    run = RunConfig(model=cfg, shape=ShapeConfig("b", S, B, "train"),
                    sharding="fsdp", param_dtype="float32",
                    activation_dtype="float32")

    def measure(overlap):
        plan = ParallelPlan.for_run(run, mesh, grad_bucket_mb=0.25,
                                    overlap=overlap)
        runner = StepRunner(model, run, opt, mesh, plan=plan)
        TrainLoop(runner, log_every=8).run(batches(1), 3)  # warm compile
        _, log = TrainLoop(runner, log_every=1).run(batches(2), STEPS)
        t = log.telemetry
        return {"stall": t["stall_fraction"],
                "step_ms": t["step_time_ema"] * 1e3,
                "tokens_per_s": t["tokens_per_s"],
                "n_buckets": t["grad_buckets"],
                "comm_mb": t["grad_comm_bytes"] / 1e6,
                "wire_mb": t["grad_wire_bytes_per_device"] / 1e6,
                "gather_mb": t["param_gather_bytes"] / 1e6,
                "losses": [m["loss"] for m in log.metrics]}

    out["fused"] = measure(False)
    out["scatter"] = measure(True)

    # gradient wire bytes vs a ddp ring all-reduce of the same payload
    info = StepRunner(model, run, opt, mesh,
                      plan=ParallelPlan.for_run(
                          run, mesh, grad_bucket_mb=0.25)).grad_sync_info()
    ddp_wire = gradsync.ring_allreduce_bytes(info["comm_bytes"], 8)
    out["wire_ratio_vs_ddp"] = info["wire_bytes_per_device"] / ddp_wire

    # -- peak-memory delta of donate_gather ------------------------------
    # donate=True differentiates from the shards (gather inside the vjp;
    # its transpose IS the per-bucket psum_scatter), so backward hands
    # each bucket's full-width grad buffer straight to the collective
    # instead of materializing the full f32 grad tree.  XLA's liveness
    # already frees per-bucket on the explicit-scatter path, so the
    # measured delta documents how much (if anything) remains.
    from repro.data.device_prefetch import place_on

    mem = {}
    for dg in (False, True):
        plan = ParallelPlan.for_run(run, mesh, grad_bucket_mb=0.25,
                                    donate_gather=dg)
        runner = StepRunner(model, run, opt, mesh, plan=plan)
        state = runner.init_state(0)
        pbatch = {k: place_on(jnp.asarray(v),
                              runner.batch_shardings.get(k))
                  for k, v in next(batches(3)).items()}
        runner.compile(state, pbatch)
        ma = runner.compiled.memory_analysis()
        mem["donate" if dg else "hold"] = {
            "temp_bytes": int(ma.temp_size_in_bytes),
            "arg_bytes": int(ma.argument_size_in_bytes),
        }
    out["peak_memory"] = mem
    out["peak_memory"]["delta_bytes"] = (
        mem["hold"]["temp_bytes"] - mem["donate"]["temp_bytes"])

    # -- per-layer regather (free_after_use) trade -----------------------
    # on the microbatch-accumulation path the gathered full-width params
    # otherwise stay live across every microbatch; free_after_use wraps
    # each bucket's gather in jax.checkpoint so backward re-gathers it
    # instead — peak temp memory drops, gather wire doubles.  Measure
    # both sides so the flip point is a number, not a guess.
    run4 = dataclasses.replace(run, microbatch=4)
    re = {}
    for fr in (False, True):
        plan = ParallelPlan.for_run(run4, mesh, grad_bucket_mb=0.25,
                                    free_after_use=fr)
        runner = StepRunner(model, run4, opt, mesh, plan=plan)
        state = runner.init_state(0)
        pbatch = {k: place_on(jnp.asarray(v),
                              runner.batch_shardings.get(k))
                  for k, v in next(batches(3)).items()}
        runner.compile(state, pbatch)
        ma = runner.compiled.memory_analysis()
        gather_mb = runner.grad_sync_info()["param_gather_bytes"] / 1e6
        re["regather" if fr else "hold"] = {
            "temp_bytes": int(ma.temp_size_in_bytes),
            # hold: one gather per step, outside the microbatch scan;
            # regather: one gather + one backward re-gather per
            # microbatch (2 x n_micro)
            "gather_wire_mb": gather_mb * (2 * 4 if fr else 1),
        }
    re["delta_bytes"] = (re["hold"]["temp_bytes"]
                         - re["regather"]["temp_bytes"])
    out["regather"] = re
    print(json.dumps(out))


def bench_fsdp_overlap():
    import subprocess
    import sys as _sys

    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + (os.pathsep + env["PYTHONPATH"]
                               if env.get("PYTHONPATH") else "")
    t0 = time.perf_counter()
    proc = subprocess.run(
        [_sys.executable, os.path.abspath(__file__),
         "--fsdp-overlap-worker"],
        env=env, capture_output=True, text=True, timeout=900)
    us = (time.perf_counter() - t0) * 1e6
    assert proc.returncode == 0, proc.stderr[-3000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])

    f, s = out["fused"], out["scatter"]
    emit(name="fsdp_overlap_step", us=us,
         derived=(f"step_fused={f['step_ms']:.1f}ms_scatter="
                  f"{s['step_ms']:.1f}ms_buckets={s['n_buckets']}"
                  f"_comm={s['comm_mb']:.2f}MB_wire={s['wire_mb']:.2f}"
                  f"MB/dev_gather={s['gather_mb']:.2f}MB"))
    emit(name="fsdp_overlap_stall", us=0,
         derived=(f"stall_fused={f['stall']:.3f}_stall_scatter="
                  f"{s['stall']:.3f}"))
    e1, e4 = out["equiv"]["1"], out["equiv"]["4"]
    traj = max(abs(a - b) / max(abs(a), 1e-9)
               for a, b in zip(f["losses"], s["losses"]))
    emit(name="fsdp_overlap_equiv", us=0,
         derived=(f"err_over_tol_micro1={e1['worst_err_over_tol']:.2f}"
                  f"_micro4={e4['worst_err_over_tol']:.2f}"
                  f"_traj_rel={traj:.1e}"
                  f"_wire_vs_ddp={out['wire_ratio_vs_ddp']:.2f}x"))
    pm = out["peak_memory"]
    emit(name="fsdp_overlap_peak_mem", us=0,
         derived=(f"temp_hold={pm['hold']['temp_bytes']/1e6:.2f}MB"
                  f"_temp_donate={pm['donate']['temp_bytes']/1e6:.2f}MB"
                  f"_delta={pm['delta_bytes']/1e6:.2f}MB"))
    rg = out["regather"]
    emit(name="fsdp_overlap_regather", us=0,
         derived=(f"temp_hold={rg['hold']['temp_bytes']/1e6:.2f}MB"
                  f"_temp_regather="
                  f"{rg['regather']['temp_bytes']/1e6:.2f}MB"
                  f"_delta={rg['delta_bytes']/1e6:.2f}MB"
                  f"_gather={rg['hold']['gather_wire_mb']:.2f}MB/dev"
                  f"_regather_gather="
                  f"{rg['regather']['gather_wire_mb']:.2f}MB/dev"))
    for e in (e1, e4):
        assert e["worst_err_over_tol"] <= 1.0 and e["loss_match"], (
            "scatter fsdp grads must match the fused reference", out)
    assert len(f["losses"]) == len(s["losses"]) == 20
    # per-step losses drift by fp reduction-order noise only; 1e-5
    # relative bounds 20 steps of f32 Adam on matching gradients
    assert traj <= 1e-5, ("scatter fsdp loss trajectory must match the "
                          "XLA-fused baseline", out)
    # reduce-scatter alone is half a ring all-reduce; a small replicated
    # (psum) remainder can nudge the ratio above exactly 0.5
    assert out["wire_ratio_vs_ddp"] <= 0.6, out
    assert s["stall"] <= f["stall"] + 0.05, (
        "scatter-overlap dispatch stall must not exceed the fused fsdp "
        "baseline", out)


def _pipeline_overlap_worker():
    """Runs in a subprocess with 8 virtual CPU devices (2 pipeline
    stages x 4-wide data axis); prints one JSON line.  The acceptance
    surface of the pipeline-parallel subsystem
    (``distributed/pipeline.py``):

      equivalence — staged 1F1B gradients vs the unpipelined
                    single-device reference at microbatch counts 2 and
                    8, and a 20-step 1F1B loss trajectory vs the
                    bucketed-ddp runner on the same batches
      bubble      — the schedule-table idle fraction must not exceed
                    the analytic ``(S-1)/(S-1+M)`` bound x 1.25
      telemetry   — step time + stall for gpipe vs 1f1b, grad bucket
                    layout, per-step activation ppermute volume
    """
    import dataclasses

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config, reduced
    from repro.configs.base import RunConfig, ShapeConfig
    from repro.distributed.sharding import ParallelPlan
    from repro.launch.mesh import make_host_mesh
    from repro.models import build_model
    from repro.train.optimizer import AdamWConfig
    from repro.train.runner import StepRunner, TrainLoop
    from repro.train.train_step import init_state, make_grad_fn

    B, S, STEPS, STAGES = 32, 64, 20, 2
    cfg = dataclasses.replace(reduced(get_config("bert-mlm-120m"),
                                      d_model=128),
                              vocab_size=512, max_position=S)
    # the reduced schedule is 1 block; pipelining needs a
    # stage-divisible stack — 4 layers over 2 stages
    g = cfg.schedule[0]
    cfg = dataclasses.replace(
        cfg, schedule=(dataclasses.replace(g, pattern=g.pattern[:1],
                                           repeats=4),))
    model = build_model(cfg)
    mesh = make_host_mesh(data=4, pipe=STAGES)
    opt = AdamWConfig(total_steps=STEPS)
    out = {"equiv": {}, "bubble": {}}

    def batches(seed=0):
        rng = np.random.default_rng(seed)
        while True:
            toks = rng.integers(4, cfg.vocab_size, (B, S)).astype(np.int32)
            yield {"tokens": toks, "labels": toks,
                   "loss_mask": np.ones((B, S), np.float32)}

    # -- gradient equivalence at microbatch counts 2 and 8 ---------------
    for n_micro in (2, 8):
        run = RunConfig(model=cfg, shape=ShapeConfig("b", S, B, "train"),
                        sharding="pp_dp", pp_schedule="1f1b",
                        param_dtype="float32",
                        activation_dtype="float32", microbatch=n_micro)
        params = init_state(model, jax.random.PRNGKey(0), run)["params"]
        batch = {k: jnp.asarray(v) for k, v in next(batches(7)).items()}
        ref_run = dataclasses.replace(run, sharding="ddp")
        _, gref, mref = jax.jit(make_grad_fn(model, ref_run))(params,
                                                              batch)
        plan = ParallelPlan.for_run(run, mesh, grad_bucket_mb=0.25)
        assert plan.grad_sync == "pipe_overlap", plan.describe()
        _, gp, mp = jax.jit(make_grad_fn(model, run, mesh, plan))(
            params, batch)
        worst = 0.0
        for a, b in zip(jax.tree_util.tree_leaves(gref),
                        jax.tree_util.tree_leaves(gp)):
            a, b = np.asarray(a), np.asarray(b)
            tol = 1e-6 * max(float(np.abs(a).max()), 1.0) + 1e-8
            worst = max(worst, float(np.abs(a - b).max()) / tol)
        out["equiv"][str(n_micro)] = {
            "worst_err_over_tol": worst,
            "loss_match": abs(float(mref["loss"]) - float(mp["loss"]))
                          <= 1e-6 * abs(float(mref["loss"])),
        }

    # -- 20-step loss trajectory + step time / stall / bubble ------------
    M = 4

    def measure(sharding, mesh_, schedule="1f1b"):
        run = RunConfig(model=cfg, shape=ShapeConfig("b", S, B, "train"),
                        sharding=sharding, pp_schedule=schedule,
                        param_dtype="float32",
                        activation_dtype="float32", microbatch=M)
        plan = ParallelPlan.for_run(run, mesh_, grad_bucket_mb=0.25)
        runner = StepRunner(model, run, opt, mesh_, plan=plan)
        gs = runner.grad_sync_info()
        TrainLoop(runner, log_every=8).run(batches(1), 3)  # warm compile
        _, log = TrainLoop(runner, log_every=1).run(batches(2), STEPS)
        t = log.telemetry
        return {"grad_sync": gs["grad_sync"],
                "stall": t["stall_fraction"],
                "step_ms": t["step_time_ema"] * 1e3,
                "n_buckets": gs["n_buckets"],
                "comm_mb": gs["comm_bytes"] / 1e6,
                "wire_mb": gs["wire_bytes_per_device"] / 1e6,
                "bubble": gs.get("bubble_fraction", 0.0),
                "bubble_analytic": gs.get("bubble_analytic", 0.0),
                "act_wire_mb":
                    gs.get("act_wire_bytes_per_device", 0.0) / 1e6,
                "buffer_depth": gs.get("pp_buffer_depth", 0),
                "losses": [m["loss"] for m in log.metrics]}

    out["baseline"] = measure("ddp", make_host_mesh(8))
    out["1f1b"] = measure("pp_dp", mesh, "1f1b")
    out["gpipe"] = measure("pp_dp", mesh, "gpipe")
    print(json.dumps(out))


def _moe_overlap_worker():
    """Runs in a subprocess with 8 virtual CPU devices (4-wide data x
    2-wide expert axis); prints one JSON line.  The acceptance surface
    of the expert-parallel MoE subsystem (``models/moe.py`` +
    ``ep_overlap``):

      equivalence — EP all_to_all-dispatch gradients vs the dense
                    one-hot single-device oracle at microbatch counts 1
                    and 4 (capacity_factor = n_experts, so no drops and
                    the two dispatches compute identical math), plus
                    the bucketed-ddp MoE path (psum'd router stats, no
                    expert axis) vs the same oracle
      trajectory  — a 20-step EP loss trajectory vs the dense bucketed
                    runner on the same batches
      telemetry   — step time for sequential vs overlapped dispatch
                    (shared-expert FFN inside the all_to_all window),
                    grad bucket layout, dispatch wire bytes
    """
    import dataclasses

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config, reduced
    from repro.configs.base import RunConfig, ShapeConfig
    from repro.distributed.sharding import ParallelPlan
    from repro.launch.mesh import make_host_mesh
    from repro.models import build_model
    from repro.train.optimizer import AdamWConfig
    from repro.train.runner import StepRunner, TrainLoop
    from repro.train.train_step import init_state, make_grad_fn

    B, S, STEPS, EP = 32, 64, 20, 2
    cfg = dataclasses.replace(reduced(get_config("mixtral-8x7b"),
                                      d_model=128),
                              vocab_size=512, max_position=S)
    # a shared expert gives the dispatch something to overlap with, and
    # capacity_factor = n_experts means no token ever drops — the EP
    # path must then reproduce the dense oracle exactly
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(
            cfg.moe, n_shared=1,
            capacity_factor=float(cfg.moe.n_experts)))
    model = build_model(cfg)
    mesh_ep = make_host_mesh(data=8 // EP, expert=EP)
    mesh_dp = make_host_mesh(8)
    opt = AdamWConfig(total_steps=STEPS)
    out = {"equiv": {}}

    def batches(seed=0):
        rng = np.random.default_rng(seed)
        while True:
            toks = rng.integers(4, cfg.vocab_size, (B, S)).astype(np.int32)
            yield {"tokens": toks, "labels": toks,
                   "loss_mask": np.ones((B, S), np.float32)}

    # -- gradient equivalence at microbatch counts 1 and 4 ---------------
    for n_micro in (1, 4):
        run = RunConfig(model=cfg, shape=ShapeConfig("b", S, B, "train"),
                        sharding="ddp", param_dtype="float32",
                        activation_dtype="float32", microbatch=n_micro)
        params = init_state(model, jax.random.PRNGKey(0), run)["params"]
        batch = {k: jnp.asarray(v) for k, v in next(batches(7)).items()}
        # the Switch aux is nonlinear in each microbatch's row set, and
        # the sharded paths split microbatches per-shard while the
        # single-device reference chunks the global batch contiguously.
        # Permute the reference batch so its contiguous microbatch m is
        # exactly the union of the shards' m-th local slices — same
        # partition, same estimator, so grads must agree to float
        # tolerance (identity when n_micro == 1)
        r = B // 8 // n_micro
        perm = np.arange(B).reshape(8, n_micro, r)
        perm = perm.transpose(1, 0, 2).reshape(-1)
        ref_batch = {k: v[perm] for k, v in batch.items()}
        _, gref, mref = jax.jit(make_grad_fn(model, run))(params,
                                                          ref_batch)

        def worst_err(g):
            w = 0.0
            for a, b in zip(jax.tree_util.tree_leaves(gref),
                            jax.tree_util.tree_leaves(g)):
                a, b = np.asarray(a), np.asarray(b)
                tol = 1e-6 * max(float(np.abs(a).max()), 1.0) + 1e-8
                w = max(w, float(np.abs(a - b).max()) / tol)
            return w

        plan = ParallelPlan.for_run(run, mesh_ep, grad_bucket_mb=0.25)
        assert plan.grad_sync == "ep_overlap", plan.describe()
        _, ge, me = jax.jit(make_grad_fn(model, run, mesh_ep, plan))(
            params, batch)
        plan_dp = ParallelPlan.for_run(run, mesh_dp, grad_bucket_mb=0.25)
        assert plan_dp.grad_sync == "bucketed_overlap", plan_dp.describe()
        _, gb, mb = jax.jit(make_grad_fn(model, run, mesh_dp, plan_dp))(
            params, batch)
        out["equiv"][str(n_micro)] = {
            "worst_err_over_tol": worst_err(ge),
            "worst_err_over_tol_bucketed": worst_err(gb),
            "loss_match": abs(float(mref["loss"]) - float(me["loss"]))
                          <= 1e-6 * abs(float(mref["loss"])),
        }

    # -- 20-step loss trajectory + step time -----------------------------
    def measure(mesh_, ep_overlap_dispatch=True):
        run = RunConfig(model=cfg, shape=ShapeConfig("b", S, B, "train"),
                        sharding="ddp", param_dtype="float32",
                        activation_dtype="float32")
        plan = ParallelPlan.for_run(
            run, mesh_, grad_bucket_mb=0.25,
            ep_overlap_dispatch=ep_overlap_dispatch)
        runner = StepRunner(model, run, opt, mesh_, plan=plan)
        gs = runner.grad_sync_info()
        TrainLoop(runner, log_every=8).run(batches(1), 3)  # warm compile
        _, log = TrainLoop(runner, log_every=1).run(batches(2), STEPS)
        t = log.telemetry
        return {"grad_sync": gs["grad_sync"],
                "stall": t["stall_fraction"],
                "step_ms": t["step_time_ema"] * 1e3,
                "n_buckets": gs["n_buckets"],
                "comm_mb": gs["comm_bytes"] / 1e6,
                "wire_mb": gs["wire_bytes_per_device"] / 1e6,
                "n_expert_buckets": gs.get("n_expert_buckets", 0),
                "dispatch_wire_mb":
                    gs.get("dispatch_wire_bytes_per_device", 0.0) / 1e6,
                "losses": [m["loss"] for m in log.metrics]}

    out["dense"] = measure(mesh_dp)
    out["sequential"] = measure(mesh_ep, ep_overlap_dispatch=False)
    out["overlap"] = measure(mesh_ep)
    print(json.dumps(out))


def bench_moe_overlap():
    import subprocess
    import sys as _sys

    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + (os.pathsep + env["PYTHONPATH"]
                               if env.get("PYTHONPATH") else "")
    t0 = time.perf_counter()
    proc = subprocess.run(
        [_sys.executable, os.path.abspath(__file__),
         "--moe-overlap-worker"],
        env=env, capture_output=True, text=True, timeout=1800)
    us = (time.perf_counter() - t0) * 1e6
    assert proc.returncode == 0, proc.stderr[-3000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])

    sq, ov, dn = out["sequential"], out["overlap"], out["dense"]
    emit(name="moe_overlap_step", us=us,
         derived=(f"step_sequential={sq['step_ms']:.1f}ms_overlap="
                  f"{ov['step_ms']:.1f}ms_dense={dn['step_ms']:.1f}ms"
                  f"_buckets={ov['n_buckets']}"
                  f"_expert_buckets={ov['n_expert_buckets']}"
                  f"_dispatch_wire={ov['dispatch_wire_mb']:.2f}MB/dev"))
    e1, e4 = out["equiv"]["1"], out["equiv"]["4"]
    traj = max(abs(a - b) / max(abs(a), 1e-9)
               for a, b in zip(dn["losses"], ov["losses"]))
    emit(name="moe_overlap_equiv", us=0,
         derived=(f"err_over_tol_micro1={e1['worst_err_over_tol']:.2f}"
                  f"_micro4={e4['worst_err_over_tol']:.2f}"
                  f"_bucketed1={e1['worst_err_over_tol_bucketed']:.2f}"
                  f"_traj_rel={traj:.1e}"))
    for e in (e1, e4):
        assert e["worst_err_over_tol"] <= 1.0 and e["loss_match"], (
            "EP all_to_all grads must match the dense one-hot oracle",
            out)
        assert e["worst_err_over_tol_bucketed"] <= 1.0, (
            "bucketed-ddp MoE grads must match the dense oracle", out)
    assert ov["grad_sync"] == sq["grad_sync"] == "ep_overlap", out
    assert dn["grad_sync"] == "bucketed_overlap", out
    assert len(dn["losses"]) == len(ov["losses"]) == 20
    # 20 steps of f32 Adam on matching gradients: reduction-order noise
    assert traj <= 1e-4, ("EP loss trajectory must match the dense "
                          "bucketed baseline", out)
    # CPU collectives are synchronous thread-rendezvous (no async DMA to
    # hide behind), so overlap can't win wall-clock here — the assert
    # pins that the overlapped schedule costs nothing vs sequential
    # (10% slack for CPU timing noise); the committed baseline ratio
    # rides the CI >15% drift gate
    assert ov["step_ms"] <= sq["step_ms"] * 1.10, (
        "overlapped dispatch step time must not exceed sequential", out)


def _tp_overlap_worker():
    """Runs in a subprocess with 8 virtual CPU devices (4-wide data x
    2-wide model axis); prints one JSON line.  The acceptance surface of
    the tensor-parallel subsystem (``tp_overlap``):

      equivalence — gradients from the explicitly-scheduled sequence-
                    parallel step (one all_gather entering each block's
                    parallel region, one psum_scatter leaving it) vs the
                    single-device fused reference at microbatch counts 1
                    and 4, for both pure "tp" and the composed "fsdp_tp"
                    (ZeRO-3 over data x TP over model) mode
      trajectory  — a 20-step fsdp_tp loss trajectory vs the XLA
                    partitioner path (``overlap=False``) on the same
                    mesh and batches
      telemetry   — step time fused vs tp_overlap, grad bucket layout,
                    activation-collective wire bytes per device
    """
    import dataclasses

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config, reduced
    from repro.configs.base import RunConfig, ShapeConfig
    from repro.distributed.sharding import ParallelPlan
    from repro.launch.mesh import make_host_mesh
    from repro.models import build_model
    from repro.train.optimizer import AdamWConfig
    from repro.train.runner import StepRunner, TrainLoop
    from repro.train.train_step import init_state, make_grad_fn

    B, S, STEPS, TP = 32, 64, 20, 2
    cfg = dataclasses.replace(reduced(get_config("bert-mlm-120m"),
                                      d_model=128),
                              vocab_size=512, max_position=S)
    model = build_model(cfg)
    n_dp = 8 // TP
    mesh = make_host_mesh(data=n_dp, model=TP)
    opt = AdamWConfig(total_steps=STEPS)
    out = {"equiv": {}}

    def batches(seed=0):
        rng = np.random.default_rng(seed)
        while True:
            toks = rng.integers(4, cfg.vocab_size, (B, S)).astype(np.int32)
            yield {"tokens": toks, "labels": toks,
                   "loss_mask": np.ones((B, S), np.float32)}

    # -- gradient equivalence at microbatch counts 1 and 4 ---------------
    for n_micro in (1, 4):
        # the sharded step splits microbatches per dp shard while the
        # single-device reference chunks the global batch contiguously;
        # permute the reference batch so its contiguous microbatch m is
        # the union of the shards' m-th local slices (identity at 1)
        r = B // n_dp // n_micro
        perm = np.arange(B).reshape(n_dp, n_micro, r)
        perm = perm.transpose(1, 0, 2).reshape(-1)
        res = {}
        for mode in ("tp", "fsdp_tp"):
            run = RunConfig(model=cfg,
                            shape=ShapeConfig("b", S, B, "train"),
                            sharding=mode, param_dtype="float32",
                            activation_dtype="float32",
                            microbatch=n_micro)
            params = init_state(model, jax.random.PRNGKey(0),
                                run)["params"]
            batch = {k: jnp.asarray(v)
                     for k, v in next(batches(7)).items()}
            ref_batch = {k: v[perm] for k, v in batch.items()}
            _, gref, mref = jax.jit(make_grad_fn(model, run))(
                params, ref_batch)
            plan = ParallelPlan.for_run(run, mesh, grad_bucket_mb=0.25)
            assert plan.grad_sync == "tp_overlap", plan.describe()
            _, gt, mt = jax.jit(make_grad_fn(model, run, mesh, plan))(
                params, batch)
            worst = 0.0
            for a, b in zip(jax.tree_util.tree_leaves(gref),
                            jax.tree_util.tree_leaves(gt)):
                a, b = np.asarray(a), np.asarray(b)
                tol = 1e-6 * max(float(np.abs(a).max()), 1.0) + 1e-8
                worst = max(worst, float(np.abs(a - b).max()) / tol)
            res[mode] = {
                "worst_err_over_tol": worst,
                "loss_match":
                    abs(float(mref["loss"]) - float(mt["loss"]))
                    <= 1e-6 * abs(float(mref["loss"])),
            }
        out["equiv"][str(n_micro)] = res

    # -- 20-step loss trajectory + step time -----------------------------
    def measure(overlap):
        run = RunConfig(model=cfg, shape=ShapeConfig("b", S, B, "train"),
                        sharding="fsdp_tp", param_dtype="float32",
                        activation_dtype="float32")
        plan = ParallelPlan.for_run(run, mesh, grad_bucket_mb=0.25,
                                    overlap=overlap)
        runner = StepRunner(model, run, opt, mesh, plan=plan)
        gs = runner.grad_sync_info()
        TrainLoop(runner, log_every=8).run(batches(1), 3)  # warm compile
        _, log = TrainLoop(runner, log_every=1).run(batches(2), STEPS)
        t = log.telemetry
        return {"grad_sync": gs["grad_sync"],
                "stall": t["stall_fraction"],
                "step_ms": t["step_time_ema"] * 1e3,
                "n_buckets": gs.get("n_buckets", 0),
                "wire_mb": gs.get("wire_bytes_per_device", 0.0) / 1e6,
                "tp_wire_mb":
                    gs.get("tp_wire_bytes_per_device", 0.0) / 1e6,
                "losses": [m["loss"] for m in log.metrics]}

    out["fused"] = measure(False)
    out["overlap"] = measure(True)
    print(json.dumps(out))


def bench_tp_overlap():
    import subprocess
    import sys as _sys

    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + (os.pathsep + env["PYTHONPATH"]
                               if env.get("PYTHONPATH") else "")
    t0 = time.perf_counter()
    proc = subprocess.run(
        [_sys.executable, os.path.abspath(__file__),
         "--tp-overlap-worker"],
        env=env, capture_output=True, text=True, timeout=1800)
    us = (time.perf_counter() - t0) * 1e6
    assert proc.returncode == 0, proc.stderr[-3000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])

    f, ov = out["fused"], out["overlap"]
    emit(name="tp_overlap_step", us=us,
         derived=(f"step_fused={f['step_ms']:.1f}ms_tp="
                  f"{ov['step_ms']:.1f}ms_buckets={ov['n_buckets']}"
                  f"_wire={ov['wire_mb']:.2f}MB/dev"
                  f"_act_wire={ov['tp_wire_mb']:.2f}MB/dev"))
    e1, e4 = out["equiv"]["1"], out["equiv"]["4"]
    traj = max(abs(a - b) / max(abs(a), 1e-9)
               for a, b in zip(f["losses"], ov["losses"]))
    emit(name="tp_overlap_equiv", us=0,
         derived=(f"err_over_tol_tp1="
                  f"{e1['tp']['worst_err_over_tol']:.2f}"
                  f"_tp4={e4['tp']['worst_err_over_tol']:.2f}"
                  f"_fsdptp1={e1['fsdp_tp']['worst_err_over_tol']:.2f}"
                  f"_fsdptp4={e4['fsdp_tp']['worst_err_over_tol']:.2f}"
                  f"_traj_rel={traj:.1e}"))
    for e in (e1, e4):
        for mode in ("tp", "fsdp_tp"):
            assert (e[mode]["worst_err_over_tol"] <= 1.0
                    and e[mode]["loss_match"]), (
                "tp_overlap grads must match the fused reference",
                mode, out)
    assert ov["grad_sync"] == "tp_overlap", out
    assert f["grad_sync"] == "xla_fused", out
    assert len(f["losses"]) == len(ov["losses"]) == 20
    # per-step losses drift by fp reduction-order noise only; 1e-5
    # relative bounds 20 steps of f32 Adam on matching gradients
    assert traj <= 1e-5, ("tp_overlap loss trajectory must match the "
                          "XLA-fused fsdp_tp baseline", out)
    # CPU collectives are synchronous thread-rendezvous (no async DMA to
    # hide behind), so the explicit schedule can't win wall-clock here —
    # the assert pins that it costs no more than the partitioner-fused
    # step (10% slack for CPU timing noise); the committed ratio rides
    # the CI >15% drift gate
    assert ov["step_ms"] <= f["step_ms"] * 1.10, (
        "tp_overlap step time must not exceed the fused baseline", out)


def bench_pipeline_overlap():
    import subprocess
    import sys as _sys

    from repro.distributed.pipeline import analytic_bubble

    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + (os.pathsep + env["PYTHONPATH"]
                               if env.get("PYTHONPATH") else "")
    t0 = time.perf_counter()
    proc = subprocess.run(
        [_sys.executable, os.path.abspath(__file__),
         "--pipeline-overlap-worker"],
        env=env, capture_output=True, text=True, timeout=1800)
    us = (time.perf_counter() - t0) * 1e6
    assert proc.returncode == 0, proc.stderr[-3000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])

    base, ob, og = out["baseline"], out["1f1b"], out["gpipe"]
    emit(name="pipeline_overlap_step", us=us,
         derived=(f"step_ddp={base['step_ms']:.1f}ms_1f1b="
                  f"{ob['step_ms']:.1f}ms_gpipe={og['step_ms']:.1f}ms"
                  f"_buckets={ob['n_buckets']}_act_wire="
                  f"{ob['act_wire_mb']:.2f}MB/dev"))
    emit(name="pipeline_overlap_bubble", us=0,
         derived=(f"bubble_1f1b={ob['bubble']:.3f}_gpipe="
                  f"{og['bubble']:.3f}_analytic="
                  f"{ob['bubble_analytic']:.3f}"
                  f"_depth_1f1b={ob['buffer_depth']}"
                  f"_gpipe={og['buffer_depth']}"))
    e2, e8 = out["equiv"]["2"], out["equiv"]["8"]
    traj = max(abs(a - b) / max(abs(a), 1e-9)
               for a, b in zip(base["losses"], ob["losses"]))
    emit(name="pipeline_overlap_equiv", us=0,
         derived=(f"err_over_tol_micro2={e2['worst_err_over_tol']:.2f}"
                  f"_micro8={e8['worst_err_over_tol']:.2f}"
                  f"_traj_rel={traj:.1e}"))
    for e in (e2, e8):
        assert e["worst_err_over_tol"] <= 1.0 and e["loss_match"], (
            "staged 1F1B grads must match the unpipelined reference",
            out)
    assert ob["grad_sync"] == og["grad_sync"] == "pipe_overlap", out
    assert len(base["losses"]) == len(ob["losses"]) == 20
    # 20 steps of f32 Adam on matching gradients: reduction-order noise
    assert traj <= 1e-5, ("1F1B loss trajectory must match the "
                          "unpipelined baseline", out)
    # cond-gating the bubble ticks must not change the schedule: the
    # table bubble equals the analytic (S-1)/(S-1+M) exactly
    bound = analytic_bubble(2, 4)
    assert ob["bubble"] == bound and og["bubble"] == bound, (out, bound)
    # 1F1B's memory edge: in-flight stage inputs bounded by S, not M
    assert ob["buffer_depth"] <= og["buffer_depth"], out


def bench_data_pipeline(tmp):
    """Deterministic pipeline vs the seed sampling loader.

    Rows:
      data_pipeline_throughput   ordered per-host loader samples/s vs the
                                 nondeterministic seed PrefetchLoader
      data_pipeline_sharding     2-host disjointness/coverage check + the
                                 per-host throughput when this host reads
                                 only its half of every global batch
      data_pipeline_resume       overhead of restore()+first-batch vs a
                                 cold first batch (resume cost is an
                                 integer seek, not a re-read)
    """
    import numpy as np

    from repro.data import (DataPipeline, PrefetchLoader, StagedDataset,
                            measure_throughput)

    B, N_BATCH = 64, 120
    pipe = DataPipeline.build(os.path.join(tmp, "dp"), n_functions=1500,
                              seq_len=512, batch_size=B, vocab_size=1024,
                              n_workers=2, seed=0)
    ds = pipe.ds

    # seed loader (nondeterministic shard sampler), same staged data
    m_seed = measure_throughput(StagedDataset(list(ds.shards)), B, 2,
                                n_batches=N_BATCH)

    def pipe_throughput(p):
        it = p.host_batches()
        next(it)  # warm workers
        t0 = time.perf_counter()
        for _ in range(N_BATCH):
            next(it)
        dt = time.perf_counter() - t0
        p.close()
        return N_BATCH * p.batch_size / dt

    t0 = time.perf_counter()
    sps = pipe_throughput(pipe)
    us = (time.perf_counter() - t0) * 1e6
    emit(name="data_pipeline_throughput", us=us,
         derived=(f"ordered={sps:.0f}sps_seed="
                  f"{m_seed['samples_per_s']:.0f}sps_ratio="
                  f"{sps / max(m_seed['samples_per_s'], 1e-9):.2f}x"))

    # 2-host sharding: disjoint covering halves of the global order
    host0 = DataPipeline(ds, B // 2, seed=0, process_index=0,
                         process_count=2, n_workers=2)
    host1 = DataPipeline(ds, B // 2, seed=0, process_index=1,
                         process_count=2, n_workers=2)
    for b in range(3):
        i0, i1 = host0.batch_indices(b), host1.batch_indices(b)
        assert set(i0).isdisjoint(i1) and len(set(i0) | set(i1)) == B
    t0 = time.perf_counter()
    sps0 = pipe_throughput(host0)
    us = (time.perf_counter() - t0) * 1e6
    host1.close()
    emit(name="data_pipeline_sharding", us=us,
         derived=f"disjoint=ok_perhost={sps0:.0f}sps_hosts=2")

    # resume overhead: aim a fresh pipeline mid-epoch and time to batch 1
    cold = DataPipeline(ds, B, seed=0, n_workers=2)
    t0 = time.perf_counter()
    next(cold.host_batches())
    cold_s = time.perf_counter() - t0
    cold.close()
    warm = DataPipeline(ds, B, seed=0, n_workers=2)
    warm.restore(warm.state_at(pipe.batches_per_epoch // 2))
    t0 = time.perf_counter()
    next(warm.host_batches())
    resume_s = time.perf_counter() - t0
    warm.close()
    emit(name="data_pipeline_resume", us=resume_s * 1e6,
         derived=(f"first_batch_cold={cold_s*1e3:.1f}ms_resumed="
                  f"{resume_s*1e3:.1f}ms_overhead="
                  f"{(resume_s - cold_s)*1e3:+.1f}ms"))


def bench_kernels():
    import jax
    import jax.numpy as jnp

    from repro.kernels import ref
    from repro.kernels.flash_attention import flash_attention_fwd
    from repro.kernels.fused_xent import fused_xent
    from repro.kernels.ssd_scan import ssd_scan

    ks = jax.random.split(jax.random.PRNGKey(0), 8)
    q = jax.random.normal(ks[0], (1, 256, 4, 64))
    k = jax.random.normal(ks[1], (1, 256, 2, 64))
    v = jax.random.normal(ks[2], (1, 256, 2, 64))
    us = _t(lambda: jax.block_until_ready(
        flash_attention_fwd(q, k, v, causal=True)))
    err = float(jnp.abs(flash_attention_fwd(q, k, v, causal=True)
                        - ref.flash_attention_ref(q, k, v, causal=True)).max())
    emit(name="kernel_flash_attention_interp", us=us,
                     derived=f"maxerr={err:.1e}")

    x = jax.random.normal(ks[3], (1, 256, 4, 16))
    dt = jax.nn.softplus(jax.random.normal(ks[4], (1, 256, 4)))
    A = -jnp.exp(jax.random.normal(ks[5], (4,)) * 0.5)
    Bm = jax.random.normal(ks[6], (1, 256, 1, 16))
    Cm = jax.random.normal(ks[7], (1, 256, 1, 16))
    us = _t(lambda: jax.block_until_ready(
        ssd_scan(x, dt, A, Bm, Cm, chunk=64)[0]))
    e = float(jnp.abs(ssd_scan(x, dt, A, Bm, Cm, chunk=64)[0]
                      - ref.ssd_ref(x, dt, A, Bm, Cm, chunk=64)[0]).max())
    emit(name="kernel_ssd_scan_interp", us=us,
                     derived=f"maxerr={e:.1e}")

    logits = jax.random.normal(ks[0], (512, 4096))
    labels = jax.random.randint(ks[1], (512,), 0, 4096)
    us = _t(lambda: jax.block_until_ready(fused_xent(logits, labels)))
    e = float(jnp.abs(fused_xent(logits, labels)
                      - ref.xent_ref(logits, labels)).max())
    emit(name="kernel_fused_xent_interp", us=us,
                     derived=f"maxerr={e:.1e}")


def bench_serve_bench():
    """Continuous batching + paged KV vs static lockstep batching.

    A deterministic (seeded) Poisson arrival process of mixed-length
    prompts with mixed ``max_new`` runs through both engines on the same
    params; both are warmed with an identical pass first, so jit compile
    time is excluded and the reported ratio is machine-independent.  The
    continuous engine decodes through the paged REF gather (the Pallas
    kernel runs interpret-mode-only on CPU, which benches the
    interpreter, not the layout — the kernel itself is equivalence-gated
    in tests/test_paged_attention.py).  Latencies are in decode steps:
    arrival step -> finish step, so they measure scheduling, not CPU
    speed."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config, reduced
    from repro.configs.base import RunConfig, ShapeConfig
    from repro.models import build_model
    from repro.serve import PagedServeEngine, ServeEngine

    cfg = reduced(get_config("starcoder2-3b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    run = RunConfig(model=cfg, shape=ShapeConfig("s", 16, 2, "decode"),
                    sharding="ddp", param_dtype="float32",
                    activation_dtype="float32")
    SLOTS, PAGE = 4, 8

    # workload: Poisson arrivals, mixed prompts, long-tail outputs
    rng = np.random.RandomState(7)
    N = 12
    arrivals = np.cumsum(rng.poisson(1.5, N))          # in decode steps
    prompts = [list(rng.randint(4, cfg.vocab_size, rng.randint(6, 25)))
               for _ in range(N)]
    max_new = [int(x) for x in rng.choice([2, 3, 4, 64], N,
                                          p=[0.3, 0.3, 0.3, 0.1])]
    if 64 not in max_new:
        max_new[0] = 64                                # keep the tail
    useful = sum(max_new)

    # ---- static lockstep baseline: batches of SLOTS in arrival order,
    # prompts padded to the batch max, decoded to the batch-max max_new
    legacy = ServeEngine(model=model, run=run)

    def run_static():
        lat, t_steps = [], 0
        for i in range(0, N, SLOTS):
            js = range(i, min(i + SLOTS, N))
            S0 = max(len(prompts[j]) for j in js)
            mn = max(max_new[j] for j in js)
            toks = np.zeros((len(list(js)), S0), np.int32)
            for r, j in enumerate(js):
                toks[r, S0 - len(prompts[j]):] = prompts[j]  # left-pad
            legacy.generate(params, {"tokens": jnp.asarray(toks)},
                            max_new=mn)
            t_steps += mn
            lat += [t_steps - int(arrivals[j]) for j in js]
        return lat

    eng = PagedServeEngine(model=model, run=run, page=PAGE, n_pages=256,
                           max_slots=SLOTS, max_pages=11,
                           use_pallas_decode=False)

    def run_continuous():
        base = eng._step_count          # engine reused across runs: jit
        rid2i, fin, util_peak, nxt = {}, {}, 0.0, 0   # caches stay warm
        while len(fin) < N:
            while nxt < N and arrivals[nxt] <= eng._step_count - base:
                rid2i[eng.submit(prompts[nxt], max_new[nxt],
                                 arrival=float(arrivals[nxt]))] = nxt
                nxt += 1
            for req in eng.step(params):
                fin[rid2i[req.rid]] = (req.finish_step - base
                                       - int(req.arrival))
            util_peak = max(util_peak, eng.utilization())
        run_continuous.util = util_peak
        return [fin[i] for i in range(N)]

    # warm both paths (compiles), then time identical runs; best-of-2
    # damps scheduler jitter on shared CI runners
    run_static()
    run_continuous()

    def best_of(fn, k=2):
        times, out = [], None
        for _ in range(k):
            t0 = time.perf_counter()
            out = fn()
            times.append(time.perf_counter() - t0)
        return min(times), out

    t_static, lat_s = best_of(run_static)
    t_cont, lat_c = best_of(run_continuous)
    compiles = eng.decode_compiles()
    assert compiles == 1, f"decode recompiled: {compiles} entries"
    speedup = t_static / t_cont
    assert speedup >= 2.0, \
        f"continuous only {speedup:.2f}x static (need >=2x)"

    p = lambda xs, q: float(np.percentile(xs, q))
    emit(name="serve_bench_throughput", us=t_cont * 1e6,
         derived=(f"static={t_static*1e3:.1f}ms_continuous="
                  f"{t_cont*1e3:.1f}ms_speedup={speedup:.2f}x"
                  f"_tok_s={useful/t_cont:.0f}"))
    emit(name="serve_bench_latency_steps", us=0,
         derived=(f"p50={p(lat_c,50):.0f}_p95={p(lat_c,95):.0f}"
                  f"_static_p50={p(lat_s,50):.0f}"
                  f"_static_p95={p(lat_s,95):.0f}"))
    emit(name="serve_bench_pool", us=0,
         derived=(f"util_peak={run_continuous.util:.2f}"
                  f"_util_end={eng.utilization():.2f}"
                  f"_decode_compiles={compiles}"))


def bench_roofline_table():
    recs = []
    for p in sorted(glob.glob("experiments/dryrun/*.json")):
        with open(p) as f:
            r = json.load(f)
        if "t_compute" in r:
            recs.append(r)
    if not recs:
        emit(name="roofline_table", us=0,
                         derived="no_dryrun_records_yet")
        return
    n_mem = sum(1 for r in recs if r["dominant"] == "memory")
    n_cmp = sum(1 for r in recs if r["dominant"] == "compute")
    n_col = sum(1 for r in recs if r["dominant"] == "collective")
    fits = sum(1 for r in recs if r["fits_hbm"])
    emit(
        name="roofline_table", us=0,
        derived=(f"records={len(recs)}_mem={n_mem}_compute={n_cmp}"
                 f"_coll={n_col}_fits_hbm={fits}/{len(recs)}"))


def main() -> None:
    argv = sys.argv[1:]
    if "--grad-overlap-worker" in argv:
        _grad_overlap_worker()
        return
    if "--fsdp-overlap-worker" in argv:
        _fsdp_overlap_worker()
        return
    if "--pipeline-overlap-worker" in argv:
        _pipeline_overlap_worker()
        return
    if "--moe-overlap-worker" in argv:
        _moe_overlap_worker()
        return
    if "--tp-overlap-worker" in argv:
        _tp_overlap_worker()
        return
    json_path = None
    if "--json" in argv:
        i = argv.index("--json")
        if i + 1 >= len(argv):
            sys.exit("--json needs a path argument")
        json_path = argv[i + 1]
        argv = argv[:i] + argv[i + 2:]
    meta_sha = None
    if "--meta-sha" in argv:
        i = argv.index("--meta-sha")
        if i + 1 >= len(argv):
            sys.exit("--meta-sha needs a revision argument")
        meta_sha = argv[i + 1]
        argv = argv[:i] + argv[i + 2:]
    baseline = "--baseline" in argv
    argv = [a for a in argv if a != "--baseline"]
    names = [a for a in argv if not a.startswith("-")]

    def want(bench: str) -> bool:
        return not names or any(bench.startswith(n) for n in names)

    print("name,us_per_call,derived")
    if want("r1") or want("r2") or want("r3"):
        with tempfile.TemporaryDirectory() as tmp:
            shards = bench_r1_dataset_reduction(tmp)
            if want("r2"):
                bench_r2_staging(tmp, shards)
            if want("r3"):
                bench_r3_loader_workers(tmp, shards)
    if want("fig1"):
        bench_fig1_dp_scaling()
    if want("r5"):
        bench_r5_batch_vs_model()
    if want("mlm"):
        bench_mlm_train_step()
    if want("train_overlap"):
        with tempfile.TemporaryDirectory() as tmp:
            bench_train_overlap(tmp)
    if want("trace_overhead"):
        with tempfile.TemporaryDirectory() as tmp:
            bench_trace_overhead(tmp)
    if want("grad_overlap"):
        bench_grad_overlap()
    if want("fsdp_overlap"):
        bench_fsdp_overlap()
    if want("pipeline_overlap"):
        bench_pipeline_overlap()
    if want("moe_overlap"):
        bench_moe_overlap()
    if want("tp_overlap"):
        bench_tp_overlap()
    if want("data_pipeline"):
        with tempfile.TemporaryDirectory() as tmp:
            bench_data_pipeline(tmp)
    if want("serve"):
        bench_serve_bench()
    if want("kernel"):
        bench_kernels()
    if want("roofline"):
        bench_roofline_table()
    meta = _meta(meta_sha) if (json_path or baseline) else None
    if json_path:
        with open(json_path, "w") as f:
            json.dump({"meta": meta, "rows": RESULTS}, f, indent=2)
        print(f"# wrote {len(RESULTS)} rows -> {json_path}", file=sys.stderr)
    if baseline:
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        groups = ("train_overlap", "trace_overhead", "grad_overlap",
                  "fsdp_overlap", "pipeline_overlap", "moe_overlap",
                  "tp_overlap", "data_pipeline", "mlm", "kernel", "serve")
        for g in groups:
            rows = [r for r in RESULTS if r["name"].startswith(g)]
            if not rows:
                continue
            p = os.path.join(root, f"BENCH_{g}.json")
            with open(p, "w") as f:
                json.dump({"meta": meta, "rows": rows}, f, indent=2)
            print(f"# baseline {len(rows)} rows -> {p}", file=sys.stderr)


if __name__ == "__main__":
    main()
