"""Quickstart: the paper's pipeline end to end in ~a minute on CPU.

Builds the deterministic ``DataPipeline`` over a small synthetic
binary-function corpus (R1 tokenize+pack offline, R2 stage node-locally,
R3 ordered parallel prefetch), pretrains a reduced BERT-MLM model with
resumable sharded checkpoints, then kills-and-resumes to show the loss
trajectory continuing bit-exact from the saved step.

  PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.configs.base import RunConfig, ShapeConfig
from repro.core.mlm import mask_tokens
from repro.data import DataPipeline, NetworkFS
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.train.optimizer import AdamWConfig
from repro.train.runner import StepRunner, TrainLoop, resume

SEQ, BATCH, STEPS = 64, 16, 60

with tempfile.TemporaryDirectory() as tmp:
    cfg = dataclasses.replace(reduced(get_config("bert-mlm-120m"),
                                      d_model=128),
                              vocab_size=1024, max_position=SEQ)

    def mlm_work(batch, rng):
        key = jax.random.PRNGKey(int(rng.integers(1 << 30)))
        inp, lab, m = mask_tokens(key, jnp.asarray(batch["tokens"]),
                                  cfg.vocab_size, mask_id=3)
        return {"tokens": np.asarray(inp), "labels": np.asarray(lab),
                "loss_mask": np.asarray(m) * batch["attn_mask"]}

    # R1+R2+R3 in one shot: corpus -> pack -> stage -> deterministic
    # per-host order (this is host 0 of 1; masking runs in the workers
    # with an rng keyed by the global batch index, so the stream is a
    # pure function of the cursor)
    pipeline = DataPipeline.build(
        os.path.join(tmp, "data"), n_functions=800, seq_len=SEQ,
        batch_size=BATCH, vocab_size=1024, max_merges=120,
        network=NetworkFS(agg_bw=2e9, readers=8),
        n_workers=2, seed=0, work_fn=mlm_work)
    print(f"R1+R2: packed+staged {pipeline.ds.n_examples} examples, "
          f"{pipeline.batches_per_epoch} batches/epoch")

    # train through the sharding-aware async runner: one compile with
    # explicit shardings + donated state, device-prefetched batches,
    # non-blocking metrics, background sharded checkpoints
    model = build_model(cfg)
    run = RunConfig(model=cfg, shape=ShapeConfig("q", SEQ, BATCH, "train"),
                    sharding="ddp", param_dtype="float32",
                    activation_dtype="float32")
    opt = AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=STEPS)
    runner = StepRunner(model, run, opt, make_host_mesh())
    ck = os.path.join(tmp, "ck")
    half = STEPS // 2
    loop = TrainLoop(runner, log_every=10, ckpt_dir=ck, ckpt_every=half)
    state, log = loop.run(pipeline, half)
    print(f"...'killed' after step {half}; resuming from {ck}")

    # a fresh runner + pipeline, as a restarted process would build them
    runner2 = StepRunner(model, run, opt, make_host_mesh())
    state, start = resume(ck, runner2, pipeline=pipeline)
    loop2 = TrainLoop(runner2, log_every=10, ckpt_dir=ck, ckpt_every=half)
    state, log2 = loop2.run(pipeline, STEPS, state=state, start_step=start)
    pipeline.close()
    log.steps += log2.steps
    log.metrics += log2.metrics
    log.tokens_per_s += log2.tokens_per_s
    log.telemetry = log2.telemetry
    for s, m, tps in zip(log.steps, log.metrics, log.tokens_per_s):
        print(f"step {s:3d}  mlm_xent={m['xent']:.4f}  acc={m['acc']:.3f}"
              f"  tokens/s={tps:.0f}")
    t = log.telemetry
    print(f"telemetry: step_ema={t['step_time_ema']*1e3:.1f}ms  "
          f"host_stall={t['stall_fraction']*100:.1f}%  "
          f"compiles={t['n_traces']:.0f}")
    assert log.metrics[-1]["xent"] < log.metrics[0]["xent"]
    assert t["n_traces"] == 1, "train step must compile exactly once"
    print("quickstart OK: loss decreased")
