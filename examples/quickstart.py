"""Quickstart: the paper's pipeline end to end in ~a minute on CPU.

Synthesizes a small binary-function corpus, applies the paper's three data
recommendations (R1 tokenize+pack offline, R2 stage node-locally, R3 tuned
prefetch loading), then pretrains a reduced BERT-MLM model and prints the
loss curve.

  PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.configs.base import RunConfig, ShapeConfig
from repro.core.mlm import mask_tokens
from repro.data import (ByteBPETokenizer, NetworkFS, PrefetchLoader,
                        StagedDataset, pack_corpus, read_raw_corpus,
                        size_reduction, write_raw_corpus)
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.train.optimizer import AdamWConfig
from repro.train.runner import StepRunner, TrainLoop

SEQ, BATCH, STEPS = 64, 16, 60

with tempfile.TemporaryDirectory() as tmp:
    # R1 — tokenize + pack offline, keep only ids + masks
    raw = os.path.join(tmp, "raw.jsonl")
    nbytes = write_raw_corpus(raw, 800, seed=0)
    fns = list(read_raw_corpus(raw))
    tok = ByteBPETokenizer.train(fns[:40], vocab_size=1024, max_merges=120)
    shards = pack_corpus(iter(fns), tok, os.path.join(tmp, "packed"),
                         seq_len=SEQ)
    print(f"R1: raw {nbytes/1e6:.1f}MB -> packed "
          f"(-{size_reduction(nbytes, shards)*100:.0f}%)")

    # R2 — stage to node-local storage
    ds = StagedDataset(shards, network=NetworkFS(agg_bw=2e9, readers=8),
                       local_dir=os.path.join(tmp, "local"))
    print(f"R2: staged in {ds.stage():.2f}s")

    # R3 — prefetch loader (masking happens in the workers)
    cfg = dataclasses.replace(reduced(get_config("bert-mlm-120m"),
                                      d_model=128),
                              vocab_size=1024, max_position=SEQ)

    def mlm_work(batch, rng):
        key = jax.random.PRNGKey(int(rng.integers(1 << 30)))
        inp, lab, m = mask_tokens(key, jnp.asarray(batch["tokens"]),
                                  cfg.vocab_size, mask_id=3)
        return {"tokens": np.asarray(inp), "labels": np.asarray(lab),
                "loss_mask": np.asarray(m) * batch["attn_mask"]}

    loader = PrefetchLoader(ds, BATCH, n_workers=2, work_fn=mlm_work).start()

    # train through the sharding-aware async runner: one compile with
    # explicit shardings + donated state, device-prefetched batches,
    # non-blocking metrics
    model = build_model(cfg)
    run = RunConfig(model=cfg, shape=ShapeConfig("q", SEQ, BATCH, "train"),
                    sharding="ddp", param_dtype="float32",
                    activation_dtype="float32")
    opt = AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=STEPS)
    runner = StepRunner(model, run, opt, make_host_mesh())
    state, log = TrainLoop(runner, log_every=10).run(loader, STEPS)
    loader.stop()
    for s, m, tps in zip(log.steps, log.metrics, log.tokens_per_s):
        print(f"step {s:3d}  mlm_xent={m['xent']:.4f}  acc={m['acc']:.3f}"
              f"  tokens/s={tps:.0f}")
    t = log.telemetry
    print(f"telemetry: step_ema={t['step_time_ema']*1e3:.1f}ms  "
          f"host_stall={t['stall_fraction']*100:.1f}%  "
          f"compiles={t['n_traces']:.0f}")
    assert log.metrics[-1]["xent"] < log.metrics[0]["xent"]
    assert t["n_traces"] == 1, "train step must compile exactly once"
    print("quickstart OK: loss decreased")
