"""Batched serving example: prefill + KV-cache decode across model families
(dense GQA, sliding-window, SSM, MLA) with the same engine.

  PYTHONPATH=src python examples/serve_batched.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs import get_config, reduced
from repro.configs.base import RunConfig, ShapeConfig
from repro.models import build_model
from repro.serve.engine import ServeEngine

B, S0, NEW = 4, 24, 8

for arch in ("starcoder2-3b", "gemma3-4b", "mamba2-130m",
             "deepseek-v2-lite-16b"):
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    run = RunConfig(model=cfg, shape=ShapeConfig("s", S0, B, "decode"),
                    sharding="ddp", param_dtype="float32",
                    activation_dtype="float32")
    eng = ServeEngine(model, run)
    prompts = {"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                            (B, S0), 4, cfg.vocab_size)}
    t0 = time.perf_counter()
    out = eng.generate(params, prompts, max_new=NEW, temperature=0.7,
                       seed=42)
    dt = time.perf_counter() - t0
    print(f"{arch:24s} generated {out.shape} in {dt:5.2f}s "
          f"({B*NEW/dt:6.1f} tok/s, CPU, reduced config)")
print("serve_batched OK")
