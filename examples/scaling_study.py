"""Reproduces the paper's scaling analysis (Fig. 1 + R4/R5) analytically,
then measures the async training loop's telemetry on this host.

Prints samples/s vs worker count for the 120M and 350M MLM models on the
paper's hardware (H100-NVL, 25 GbE) and on the TPU v5e target, plus the
R5 max-batch table, and finally a measured run through the sharding-aware
StepRunner/TrainLoop (step-time EMA, tokens/s, hlocost-MFU, host-stall
fraction).

  PYTHONPATH=src python examples/scaling_study.py
"""
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.configs import get_config, reduced
from repro.core import (DPScalingModel, H100_NVL, MemoryModel, TPU_V5E,
                        dp_scaling_curve)

print("== Fig. 1: DP scaling (samples/s) ==")
for arch, b in (("bert-mlm-120m", 184), ("bert-mlm-350m", 20)):
    cfg = get_config(arch)
    for chip, name in ((H100_NVL, "H100-NVL/25GbE"), (TPU_V5E, "TPUv5e/ICI")):
        curve = dp_scaling_curve(cfg, per_dev_batch=b, chip=chip, seq=512)
        xs = sorted(curve)
        line = " ".join(f"{n}:{curve[n]['samples_per_s']:.0f}" for n in xs)
        print(f"{arch:16s} b={b:3d} {name:16s} {line}")
        print(f"{'':16s}      efficiency@256 = "
              f"{curve[256]['efficiency']:.2f}")

print()
print("== R5: memory-limited max per-device batch (seq 512) ==")
for arch in ("bert-mlm-120m", "bert-mlm-350m"):
    mm = MemoryModel(get_config(arch), act_factor=150.0)
    print(f"{arch:16s} H100-NVL(94GB): {mm.max_batch(512, H100_NVL.hbm_bytes):4d}"
          f"   TPUv5e(16GB): {mm.max_batch(512, TPU_V5E.hbm_bytes):4d}")
print("paper observed: 184 (120M) vs 20 (350M) per H100")
print()
print("== R5 -> beyond-paper: state sharding recovers the batch ==")
cfg = get_config("gemma3-4b")
for shards in (1, 16, 256):
    mm = MemoryModel(cfg, state_shards=shards)
    print(f"gemma3-4b seq=4096, state sharded {shards:3d}x: "
          f"max batch/device = {mm.max_batch(4096, TPU_V5E.hbm_bytes)}")

print()
print("== measured: async loop telemetry over the deterministic pipeline ==")
import tempfile

from repro.configs.base import RunConfig, ShapeConfig
from repro.data import DataPipeline
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.train.optimizer import AdamWConfig
from repro.train.runner import StepRunner, TrainLoop

B, S, STEPS = 8, 64, 12
mcfg = dataclasses.replace(reduced(get_config("bert-mlm-120m"), d_model=128),
                           vocab_size=512, max_position=S)
model = build_model(mcfg)
run = RunConfig(model=mcfg, shape=ShapeConfig("s", S, B, "train"),
                sharding="ddp", param_dtype="float32",
                activation_dtype="float32")


def lm_work(batch, rng):
    toks = batch["tokens"]
    return {"tokens": toks, "labels": np.roll(toks, -1, axis=1),
            "loss_mask": batch["attn_mask"]}


with tempfile.TemporaryDirectory() as tmp:
    pipeline = DataPipeline.build(tmp, n_functions=300, seq_len=S,
                                  batch_size=B, vocab_size=mcfg.vocab_size,
                                  max_merges=60, n_workers=2, seed=0,
                                  work_fn=lm_work)
    runner = StepRunner(model, run, AdamWConfig(total_steps=STEPS),
                        make_host_mesh())
    _, mlog = TrainLoop(runner, log_every=4).run(pipeline, STEPS)
    pipeline.close()
t = mlog.telemetry
print(f"bert-mlm-120m(reduced) b={B} seq={S}: "
      f"step_ema={t['step_time_ema']*1e3:.1f}ms "
      f"tokens/s={t['tokens_per_s']:.0f} "
      f"host_stall={t['stall_fraction']*100:.1f}% "
      f"mfu(v5e-peak)={mlog.mfu[-1]:.2e} compiles={t['n_traces']:.0f}")
